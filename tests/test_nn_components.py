"""Unit + property tests for the NN substrate components."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.testing import given, settings, st

from repro.nn import attention as attn_lib
from repro.nn import moe as moe_lib
from repro.nn.rope import apply_rope
from repro.nn.ssm import chunked_ssm_scan, ssm_decode_step
from repro.nn.xlstm import (
    chunked_mlstm, init_mlstm_state, init_slstm_state,
    mlstm_decode_step, slstm_scan,
)


# --------------------------------------------------------------- attention

def test_chunked_attention_matches_dense():
    b, s, h, hd = 2, 64, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))

    def dense(q, k, v, window=None):
        logits = jnp.einsum("bqhd,bkhd->bhqk", q / np.sqrt(hd), k)
        qp, kp = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
        mask = qp >= kp
        if window:
            mask &= (qp - kp) < window
        logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, -1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    for chunk in (16, 32, 64):
        for window in (None, 24):
            got = attn_lib.chunked_causal_attention(
                q, k, v, chunk_size=chunk, window=window
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(dense(q, k, v, window)), atol=1e-5
            )


@given(kvh=st.sampled_from([1, 2, 4]), h=st.sampled_from([4, 8]))
@settings(max_examples=8, deadline=None)
def test_repeat_kv(kvh, h):
    kv = jnp.arange(kvh * 6, dtype=jnp.float32).reshape(1, 2, kvh, 3)
    out = attn_lib.repeat_kv(kv, h)
    assert out.shape == (1, 2, h, 3)
    reps = h // kvh
    for i in range(h):
        np.testing.assert_array_equal(out[:, :, i], kv[:, :, i // reps])


def test_ring_cache_swa_decode():
    """Ring-buffer SWA cache: decode attends to exactly the window."""
    b, h, kvh, hd, window = 1, 2, 2, 8, 4
    cache = attn_lib.init_kv_cache(b, window, kvh, hd, jnp.float32)
    keys = jax.random.normal(jax.random.PRNGKey(0), (10, b, 1, kvh, hd))
    for t in range(10):
        cache = attn_lib.cache_update(cache, keys[t], keys[t])
    assert int(cache.index) == 10
    q = jax.random.normal(jax.random.PRNGKey(1), (b, 1, h, hd))
    out = attn_lib.decode_attention(q, cache, num_heads=h, window=window)
    # Reference: dense attention over last `window` keys in time order.
    last = jnp.concatenate([keys[t] for t in range(6, 10)], axis=1)  # (b,4,kvh,hd)
    kr = attn_lib.repeat_kv(last, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q / np.sqrt(hd), kr)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), kr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def score(pq, pk):
        qr = apply_rope(q, jnp.array([pq]))
        kr = apply_rope(k, jnp.array([pk]))
        return float(jnp.sum(qr * kr))
    assert abs(score(3, 1) - score(10, 8)) < 1e-4
    assert abs(score(0, 0) - score(7, 7)) < 1e-4


# --------------------------------------------------------------------- moe

def test_moe_matches_dense_expert_sum():
    """With capacity high enough for zero drops, MoE output equals the
    explicit gate-weighted expert sum."""
    b, s, d, f, e, k = 2, 16, 8, 12, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, d))
    router = jax.random.normal(ks[1], (d, e))
    wg = jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, f, d)) / np.sqrt(f)
    out, stats = moe_lib.moe_ffn(
        x, router, wg, wu, wd, top_k=k, capacity_factor=float(e)
    )
    assert float(stats.dropped) == 0.0

    probs = jax.nn.softmax(x @ router, -1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    expert_out = jnp.stack(
        [jax.nn.silu(x @ wg[i]) * (x @ wu[i]) @ wd[i] for i in range(e)], axis=2
    )  # (b, s, e, d)
    want = jnp.einsum(
        "bske,bsed->bsd", jax.nn.one_hot(ids, e) * gates[..., None], expert_out
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_moe_capacity_drops_tokens():
    b, s, d, f, e = 1, 64, 8, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, d))
    out, stats = moe_lib.moe_ffn(
        x,
        jax.random.normal(ks[1], (d, e)),
        jax.random.normal(ks[2], (e, d, f)),
        jax.random.normal(ks[3], (e, d, f)),
        jax.random.normal(ks[4], (e, f, d)),
        top_k=2,
        capacity_factor=0.5,
    )
    assert float(stats.dropped) > 0.0
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(stats.aux_loss) > 0.0


# --------------------------------------------------------------- ssm/xlstm

@given(chunk=st.sampled_from([4, 8, 16]), seed=st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_ssm_chunked_equals_sequential(chunk, seed):
    b, s, h, dh, ds = 1, 16, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (b, s, h, dh))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, ds))
    cm = jax.random.normal(ks[4], (b, s, ds))
    h0 = jax.random.normal(ks[5], (b, h, dh, ds))
    y, hf = chunked_ssm_scan(x, dt, a, bm, cm, h0, chunk=chunk)
    hseq = h0
    for t in range(s):
        y_t, hseq = ssm_decode_step(x[:, t], dt[:, t], a, bm[:, t], cm[:, t], hseq)
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(y_t), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hseq), atol=1e-4)


@given(chunk=st.sampled_from([4, 8]), seed=st.integers(0, 3))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunked_equals_sequential(chunk, seed):
    b, s, h, dk, dv = 1, 16, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(seed + 10), 5)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ip = jax.random.normal(ks[3], (b, s, h))
    fp = jax.random.normal(ks[4], (b, s, h)) + 2.0
    st0 = init_mlstm_state(b, h, dk, dv)
    y, _ = chunked_mlstm(q, k, v, ip, fp, st0, chunk=chunk)
    stt = st0
    for t in range(s):
        y_t, stt = mlstm_decode_step(q[:, t], k[:, t], v[:, t], ip[:, t], fp[:, t], stt)
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(y_t), atol=2e-4)


def test_slstm_state_bounded():
    """Normalizer keeps sLSTM hidden state bounded despite exp gates."""
    b, s, d, h = 2, 200, 8, 2
    xg = 3.0 * jax.random.normal(jax.random.PRNGKey(0), (b, s, 4 * d))
    rw = jax.random.normal(jax.random.PRNGKey(1), (4, h, d // h, d // h)) * 0.3
    hs, _ = slstm_scan(xg, rw, init_slstm_state(b, d), h)
    assert bool(jnp.all(jnp.isfinite(hs)))
    assert float(jnp.max(jnp.abs(hs))) < 10.0
