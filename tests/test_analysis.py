"""repro.analysis (spmdlint): every checker must fire on a seeded
mutation and stay silent on the clean tree.

The wire-payload / wire-count mesh mutations need an 8-device worker
mesh and live in tests/test_multidevice.py; everything here runs on a
single host device (value-level, vmap, or pure-text checks).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro import analysis, dssfn
from repro.core import admm
from repro.core import policy as policy_lib
from repro.core.backend import SimulatedBackend
from repro.core.policy import (
    AsyncGossip,
    ExactMean,
    Gossip,
    QuantizedGossip,
    StaleMixing,
)
from repro.core.topology import (
    ExchangeSchedule,
    Hypercube,
    Ring,
    cached_exchange_schedule,
)

M = 8


def _checks(findings):
    return sorted({f.check for f in findings})


# ---------------------------------------------------------------- findings


def test_finding_schema_and_rendering():
    f = analysis.LintFinding(
        check="wire-count", subject="gossip:3", message="mismatch",
        details={"expected": 3},
    )
    d = f.to_dict()
    assert d == {
        "check": "wire-count", "severity": "error", "subject": "gossip:3",
        "message": "mismatch", "details": {"expected": 3},
    }
    assert "ERROR [wire-count] gossip:3: mismatch" in f.render()
    assert "expected = 3" in f.render()
    with pytest.raises(ValueError, match="severity"):
        analysis.LintFinding(
            check="x", subject="y", message="z", severity="fatal"
        )
    # details are evidence, not identity.
    g = dataclasses.replace(f, details={})
    assert g == f

    payload = json.loads(analysis.findings_to_json([f, g]))
    assert payload["count"] == 2 and payload["errors"] == 2
    assert payload["findings"][0]["check"] == "wire-count"
    assert analysis.render_report([]) == "spmdlint: no findings"
    assert "2 finding(s), 2 error(s)" in analysis.render_report([f, g])


# ---------------------------------------------------------------- schedule


def test_schedule_checker_clean_on_library_schedules():
    sched = cached_exchange_schedule(Hypercube(), M)
    assert analysis.check_schedule(
        sched, subject="hypercube",
        expect_inverse_closed=True, expect_symmetric=True,
    ) == []


def test_schedule_inverse_closure_mutation():
    # A directed ring IS doubly stochastic — only closure catches it.
    directed = ExchangeSchedule(
        num_workers=4,
        perms=(tuple((i, (i + 1) % 4) for i in range(4)),),
        weights=(0.5,), self_weight=0.5,
    )
    clean = analysis.check_schedule(directed, subject="directed-ring")
    assert clean == []  # without the fault-rerouting expectation
    found = analysis.check_schedule(
        directed, subject="directed-ring", expect_inverse_closed=True
    )
    assert _checks(found) == ["schedule-inverse-closure"]


def test_schedule_weight_mutations():
    perms = (tuple((i, (i + 1) % 4) for i in range(4)),)
    overweight = ExchangeSchedule(
        num_workers=4, perms=perms, weights=(0.7,), self_weight=0.5
    )
    assert _checks(analysis.check_schedule(overweight, subject="ow")) == [
        "schedule-doubly-stochastic", "schedule-weight-sum",
    ]
    negative = ExchangeSchedule(
        num_workers=4, perms=perms, weights=(-0.2,), self_weight=1.2
    )
    assert _checks(analysis.check_schedule(negative, subject="neg")) == [
        "schedule-nonnegative", "schedule-weights",
    ]
    asym = ExchangeSchedule(
        num_workers=4, perms=perms, weights=(0.5,), self_weight=0.5
    )
    assert _checks(analysis.check_schedule(
        asym, subject="asym", expect_symmetric=True
    )) == ["schedule-symmetry"]


def test_policy_schedules_clean_across_grammar():
    for entry, policy in analysis.grammar.parse_all(M):
        assert analysis.check_policy_schedules(
            policy, M, subject=entry.spec
        ) == [], entry.spec


# ---------------------------------------------------------------- numerics


def test_numerics_accum_mutation_fires():
    def f16_prog(a, b):
        return (a.astype(jnp.float16) @ b.astype(jnp.float16)).astype(
            jnp.float32
        )

    a = jnp.zeros((4, 8), jnp.float32)
    b = jnp.zeros((8, 4), jnp.float32)
    found = analysis.lint_jax_callable(f16_prog, a, b, subject="f16-accum")
    assert "numerics-accum" in _checks(found)
    assert any(f.details.get("dtype") == "f16" for f in found)
    # The f32 form of the same program is clean.
    assert analysis.lint_jax_callable(
        lambda a, b: a @ b, a, b, subject="f32-accum"
    ) == []


def test_numerics_cholesky_guard_detection():
    g = jnp.eye(6) * 2.0
    raw = analysis.lint_jax_callable(
        jnp.linalg.cholesky, g, subject="raw-cholesky"
    )
    assert _checks(raw) == ["numerics-cholesky"]
    guarded = analysis.lint_jax_callable(
        lambda m: admm.guarded_cholesky(m)[0], g, subject="guarded"
    )
    assert "numerics-cholesky" not in _checks(guarded)


def test_numerics_backend_program_clean():
    backend = SimulatedBackend(4)
    x = jnp.ones((4, 3, 5))

    def worker(x_m):
        return x_m @ x_m.T

    assert analysis.lint_backend_program(
        backend, worker, x, subject="sim-worker"
    ) == []


# ---------------------------------------------------------------- retrace


@dataclasses.dataclass(frozen=True)
class LeakyGossip(Gossip):
    """Mutation: a config field excluded from equality/hash — two
    distinct configurations share one cached executable."""

    hidden: int = dataclasses.field(default=1, compare=False)


def test_retrace_value_level_clean_across_grammar():
    for entry, policy in analysis.grammar.parse_all(M):
        assert analysis.check_policy_cache_key(
            policy, M, subject=entry.spec
        ) == [], entry.spec


def test_retrace_key_collision_mutation_fires():
    found = analysis.check_policy_cache_key(
        LeakyGossip(rounds=2), M, subject="leaky"
    )
    assert _checks(found) == ["retrace-key-collision"]
    assert any(f.details.get("field") == "hidden" for f in found)


def test_perturb_policy_varies_every_constructible_field():
    base = AsyncGossip(
        interval=2, rounds=2, topology=Ring(2),
        faults=policy_lib.FaultModel(drop=0.1, seed=3),
    )
    variants = dict(analysis.perturb_policy(base, M))
    for field_name in ("interval", "rounds", "topology", "faults"):
        assert field_name in variants
        assert variants[field_name] != base
        variants[field_name].validate(M)


def test_backend_retrace_probe_clean():
    backend = SimulatedBackend(4)
    assert analysis.check_backend_retrace(
        backend, Gossip(rounds=2), 4, subject="gossip:2"
    ) == []
    # The probe itself populated the cache: base + 2 perturbed variants.
    info = backend.cache_info()
    assert info["entries"] == 3 and info["cache_hits"] >= 1


def test_cache_info_schema_checker():
    ok = {"entries": 1, "lowerings": 2, "cache_hits": 0, "keys": ["k"]}
    assert analysis.check_cache_info_schema(ok, subject="s") == []
    missing = analysis.check_cache_info_schema(
        {"entries": 1}, subject="s"
    )
    assert _checks(missing) == ["retrace-cache-schema"]
    skewed = analysis.check_cache_info_schema(
        {**ok, "keys": []}, subject="s"
    )
    assert _checks(skewed) == ["retrace-cache-schema"]


# ---------------------------------------------------------------- wire model


def test_expected_mix_collectives_model():
    assert analysis.expected_mix_collectives(ExactMean(), M) == {
        "all-reduce": 1
    }
    # pmean forms: no topology -> one physical all-reduce per mix.
    assert analysis.expected_mix_collectives(QuantizedGossip(bits=8), M) == {
        "all-reduce": 1
    }
    g = Gossip(rounds=3)
    assert analysis.expected_mix_collectives(g, M) == {
        "collective-permute": g.hops_for(M)
    }
    stale = StaleMixing(1, topology=Ring(2))
    hops = len(cached_exchange_schedule(Ring(2), M).perms)
    assert analysis.expected_mix_collectives(stale, M) == {
        "collective-permute": hops
    }


def test_probe_iters_rounds_to_interval():
    assert analysis.wire.probe_iters(ExactMean(), 8) == 8
    sparse = AsyncGossip(interval=4)
    assert analysis.wire.probe_iters(sparse, 6) == 8
    assert analysis.wire.probe_iters(sparse, 1) == 4


# ---------------------------------------------------------------- source


_BAD_SOURCE = """
import time
import jax


def make_key():
    return jax.random.PRNGKey(int(time.time()))


class P:
    def mix(self, x, state, ctx):
        if x.sum() > 0:
            return x, state
        return -x, state
"""

_CLEAN_SOURCE = """
import jax


def make_key():
    return jax.random.PRNGKey(0)


class P:
    rounds = 2

    def mix(self, x, state, ctx):
        if state is None:
            state = 0
        if self.rounds > 0:
            return x, state
        return -x, state
"""


def test_source_lint_mutations_fire():
    found = analysis.lint_source_text(_BAD_SOURCE, filename="bad.py")
    assert _checks(found) == ["source-prng-seed", "source-traced-branch"]
    assert analysis.lint_source_text(_CLEAN_SOURCE, filename="ok.py") == []
    broken = analysis.lint_source_text("def f(:\n", filename="broken.py")
    assert _checks(broken) == ["source-syntax"]


def test_source_lint_clean_over_repo():
    from pathlib import Path

    src_root = Path(__file__).resolve().parents[1] / "src" / "repro"
    assert analysis.lint_source_tree(src_root) == []


# ---------------------------------------------------------------- grammar


def test_grammar_table_parses_and_validates():
    parsed = analysis.grammar.parse_all(M)
    assert len(parsed) == len(analysis.ALL_GRAMMAR)
    # Every supported mode appears at least once.
    heads = {
        e.spec.split("@")[0].split(":")[0] for e in analysis.ALL_GRAMMAR
    }
    assert heads == set(policy_lib._MODES)
    wire = set(analysis.grammar_specs(wire_only=True))
    assert wire < set(analysis.grammar_specs())
    assert "async:rounds=2@ring:1+hypercube" not in wire
    assert "gossip:2@geometric:0.9" not in wire


def test_malformed_specs_rejected():
    # Full round-trip lives in test_dssfn.py; here: table shape only.
    assert len(analysis.MALFORMED_SPECS) >= 20
    assert len({s for s, _ in analysis.MALFORMED_SPECS}) == len(
        analysis.MALFORMED_SPECS
    )


# ---------------------------------------------------------------- CLI


def test_cli_clean_on_device_free_checks(tmp_path, capsys):
    from repro.launch import lint_dssfn

    args = lint_dssfn.parse_args(
        ["--checks", "schedule,retrace,source", "--all-grammar"]
    )
    assert lint_dssfn.lint(args) == []

    out = tmp_path / "findings.json"
    rc = lint_dssfn.main([
        "--checks", "schedule,source", "--all-grammar",
        "--format", "json", "--out", str(out),
    ])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["count"] == 0 and payload["findings"] == []
    assert json.loads(capsys.readouterr().out)["errors"] == 0


def test_cli_reports_grammar_parse_failure():
    from repro.launch import lint_dssfn

    rc = lint_dssfn.main(
        ["--spec", "bogus", "--checks", "schedule", "--format", "json"]
    )
    assert rc == 1


def test_cli_rejects_unknown_check():
    from repro.launch import lint_dssfn

    with pytest.raises(SystemExit, match="unknown checks"):
        lint_dssfn.lint(lint_dssfn.parse_args(["--checks", "vibes"]))


def test_dssfn_exports_analysis_surface():
    assert dssfn.parse_spec("exact") == ExactMean()
    for name in ("ALL_GRAMMAR", "check_wire_contract", "LintFinding"):
        assert hasattr(analysis, name)


# ---------------------------------------------------------------- serve

def test_serve_surface_clean():
    """The serve lint over the real engine across the feature grammar:
    zero findings on a healthy tree (no collectives leak into the
    single-device bucket programs, f32 accumulation throughout)."""
    assert analysis.check_serve_surface(buckets=(1, 4)) == []


def test_serve_lint_fires_on_bf16_engine():
    """Mutation: a half-precision engine accumulates its propagate dots
    in bf16 — the dtype-discipline rule must fire."""
    engine = analysis.synthetic_serve_engine(
        dtype=jnp.bfloat16, buckets=(1,)
    )
    findings = analysis.check_serve_contract(engine, subject="serve:bf16")
    assert "numerics-accum" in {f.check for f in findings}


def test_serve_lint_fires_on_collective():
    """Mutation: a bucket program whose compiled HLO carries a
    collective means SPMD machinery leaked into the request path."""
    hlo = "\n".join([
        "ENTRY %main (p: f32[8]) -> f32[8] {",
        "  %p = f32[8]{0} parameter(0)",
        "  ROOT %ar = f32[8]{0} all-reduce(f32[8]{0} %p), "
        "replica_groups={{0,1,2,3}}, to_apply=%add",
        "}",
    ])
    findings = analysis.check_serve_texts(
        {"stablehlo": "", "hlo": hlo}, subject="serve:mutated"
    )
    assert [f.check for f in findings] == ["serve-collective"]
    assert findings[0].details["collective_counts"] == {"all-reduce": 1}


def test_serve_lint_probe_is_compile_only():
    """The lint must not touch the serving executable cache: lowerings
    and entries are unchanged after a full contract check."""
    engine = analysis.synthetic_serve_engine(buckets=(1, 4))
    x = jnp.zeros((engine.request_dim, 1))
    engine.forward(x)                       # one real lowering
    before = engine.cache_info()
    findings = analysis.check_serve_contract(engine, subject="serve:purity")
    assert findings == []
    assert engine.cache_info() == before


def test_serve_check_registered_in_cli():
    from repro.launch import lint_dssfn

    assert "serve" in lint_dssfn.CHECKS
    args = lint_dssfn.parse_args(["--checks", "serve", "--spec", "exact"])
    assert lint_dssfn.lint(args) == []
