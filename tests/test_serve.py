"""dSSFN serving: export/load round-trips, corruption rejection,
centralized-equivalence serving parity, batching invariance, and
compile-count contracts.

The serving PR's acceptance criteria as tests:

- an exported artifact loads back bit-exactly and survives the same
  corruption drills the checkpoint store does (``is_valid_artifact``);
- ``ServeEngine`` forward is BIT-IDENTICAL (f32) to the training-time
  propagate path (``ssfn.predict``) on the same inputs, for stacks
  trained on both the vmap ``SimulatedBackend`` and the shard_map
  ``MeshBackend`` — the serving half of the paper's centralized
  equivalence;
- padded, bucketed, and micro-batched execution return the unbatched
  forward bit for bit (every op is column-wise, so pad columns cannot
  perturb real ones);
- N requests across 2 buckets cost exactly 2 lowerings; repeats are
  cache hits (the ConsensusBackend executable-cache contract, ported).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dssfn
from repro.core import ssfn
from repro.serve import (
    ArtifactCorruptError,
    MicroBatcher,
    ServeEngine,
    export_artifact,
    export_from_checkpoint,
    is_valid_artifact,
    load_artifact,
    parse_features,
)
from repro.serve.export import MANIFEST_NAME, WEIGHTS_NAME


def _data(key, m=4, p=8, q=3, jm=16):
    kx, kt = jax.random.split(key)
    xw = jax.random.normal(kx, (m, p, jm))
    labels = jax.random.randint(kt, (m, jm), 0, q)
    tw = jax.nn.one_hot(labels, q).transpose(0, 2, 1)
    return xw, tw


def _cfg(**kw):
    defaults = dict(
        input_dim=8, num_classes=3, num_layers=2, hidden=20, admm_iters=30
    )
    defaults.update(kw)
    return ssfn.SSFNConfig(**defaults)


def _train(backend="simulated", *, seed=0, **cfg_kw):
    xw, tw = _data(jax.random.PRNGKey(seed))
    spec = dssfn.TrainSpec(cfg=_cfg(**cfg_kw), backend=backend, workers=4)
    return dssfn.train(spec, xw, tw, jax.random.PRNGKey(seed + 1))


@pytest.fixture(scope="module")
def trained():
    return _train()


@pytest.fixture(scope="module")
def artifact_dir(trained, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serve") / "stack")
    export_artifact(path, trained)
    return path


# ---------------------------------------------------------------------------
# Export / load round-trip
# ---------------------------------------------------------------------------


def test_export_load_roundtrip_bit_exact(trained, artifact_dir):
    art = load_artifact(artifact_dir)
    assert art.num_classes == 3
    assert art.input_dim == 8
    assert art.num_layers == 2
    assert art.features is None
    for a, b in zip(art.params.o, trained.params.o):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(art.params.r, trained.params.r):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_export_accepts_params_and_result(trained, tmp_path):
    p1, p2 = str(tmp_path / "a"), str(tmp_path / "b")
    export_artifact(p1, trained)          # TrainResult (has .params)
    export_artifact(p2, trained.params)   # bare SSFNParams
    a1, a2 = load_artifact(p1), load_artifact(p2)
    for x, y in zip(a1.params.o, a2.params.o):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_export_rejects_non_params(tmp_path):
    with pytest.raises(TypeError, match="SSFNParams"):
        export_artifact(str(tmp_path / "bad"), {"o": [], "r": []})


def test_export_validates_feature_spec_eagerly(trained, tmp_path):
    with pytest.raises(ValueError, match="feature spec"):
        export_artifact(str(tmp_path / "bad"), trained, features="rff")
    assert not os.path.exists(str(tmp_path / "bad"))


def test_export_from_checkpoint_matches_direct_export(tmp_path):
    ck = str(tmp_path / "ckpt")
    result = _train()
    xw, tw = _data(jax.random.PRNGKey(0))
    spec = dssfn.TrainSpec(
        cfg=_cfg(), backend="simulated", workers=4,
        checkpoint_dir=ck, checkpoint_every=1,
    )
    result = dssfn.train(spec, xw, tw, jax.random.PRNGKey(1))
    path = str(tmp_path / "art")
    export_from_checkpoint(ck, path)
    art = load_artifact(path)
    for a, b in zip(art.params.o, result.params.o):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(art.params.r, result.params.r):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_export_from_missing_checkpoint_raises(tmp_path):
    with pytest.raises(ArtifactCorruptError):
        export_from_checkpoint(str(tmp_path / "nope"), str(tmp_path / "art"))


# ---------------------------------------------------------------------------
# Corruption drills (mirrors the PR-7 checkpoint hardening)
# ---------------------------------------------------------------------------


def _copy_artifact(src, dst):
    os.makedirs(dst, exist_ok=True)
    for name in (MANIFEST_NAME, WEIGHTS_NAME):
        with open(os.path.join(src, name), "rb") as f:
            blob = f.read()
        with open(os.path.join(dst, name), "wb") as f:
            f.write(blob)
    return dst


def test_valid_artifact_is_valid(artifact_dir):
    assert is_valid_artifact(artifact_dir)


def test_missing_dir_invalid(tmp_path):
    assert not is_valid_artifact(str(tmp_path / "nothing"))
    with pytest.raises(ArtifactCorruptError):
        load_artifact(str(tmp_path / "nothing"))


def test_missing_manifest_invalid(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "no_manifest"))
    os.remove(os.path.join(bad, MANIFEST_NAME))
    assert not is_valid_artifact(bad)


def test_missing_weights_invalid(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "no_weights"))
    os.remove(os.path.join(bad, WEIGHTS_NAME))
    assert not is_valid_artifact(bad)
    with pytest.raises(ArtifactCorruptError):
        load_artifact(bad)


def test_truncated_weights_invalid(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "truncated"))
    wpath = os.path.join(bad, WEIGHTS_NAME)
    blob = open(wpath, "rb").read()
    with open(wpath, "wb") as f:
        f.write(blob[: len(blob) // 2])
    assert not is_valid_artifact(bad)


def test_garbage_manifest_invalid(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "garbage"))
    with open(os.path.join(bad, MANIFEST_NAME), "w") as f:
        f.write("{not json")
    assert not is_valid_artifact(bad)


def test_future_version_invalid(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "future"))
    mpath = os.path.join(bad, MANIFEST_NAME)
    manifest = json.load(open(mpath))
    manifest["version"] = 999
    json.dump(manifest, open(mpath, "w"))
    assert not is_valid_artifact(bad)
    with pytest.raises(ArtifactCorruptError, match="version"):
        load_artifact(bad)


def test_manifest_weights_mismatch_invalid(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "mismatch"))
    mpath = os.path.join(bad, MANIFEST_NAME)
    manifest = json.load(open(mpath))
    manifest["num_classes"] = manifest["num_classes"] + 1
    json.dump(manifest, open(mpath, "w"))
    assert not is_valid_artifact(bad)


def test_engine_refuses_corrupt_artifact(artifact_dir, tmp_path):
    bad = _copy_artifact(artifact_dir, str(tmp_path / "engine_corrupt"))
    os.remove(os.path.join(bad, WEIGHTS_NAME))
    with pytest.raises(ArtifactCorruptError):
        ServeEngine(bad)


# ---------------------------------------------------------------------------
# Centralized-equivalence serving parity (both backends)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["simulated", "mesh"])
def test_engine_bit_exact_vs_training_propagate(backend, tmp_path):
    """ServeEngine forward == ssfn.predict bit for bit (f32), for stacks
    trained on both consensus backends.  J == bucket size, so no padding
    is involved and the comparison is strict.  The mesh run uses a
    1-worker mesh (tests are single-device; the shard_map program is the
    same one an M-device mesh lowers)."""
    if backend == "mesh":
        from repro.core.backend import MeshBackend
        from repro.launch.mesh import make_worker_mesh

        xw, tw = _data(jax.random.PRNGKey(0), m=1, jm=64)
        spec = dssfn.TrainSpec(
            cfg=_cfg(), backend=MeshBackend(make_worker_mesh(1))
        )
        result = dssfn.train(spec, xw, tw, jax.random.PRNGKey(1))
    else:
        result = _train(backend)
    path = str(tmp_path / "stack")
    export_artifact(path, result)
    engine = ServeEngine(path, buckets=(16,))
    x = _data(jax.random.PRNGKey(0))[0]          # (m, p, jm)
    x = np.asarray(x.transpose(1, 0, 2).reshape(8, -1))[:, :16]
    ref = ssfn.predict(result.params, jnp.asarray(x), 3)
    out = engine.forward(x)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    assert np.array_equal(
        np.asarray(engine.classify(x)), np.asarray(jnp.argmax(ref, axis=0))
    )


def test_reload_hot_swap_no_recompile(artifact_dir, tmp_path):
    engine = ServeEngine(artifact_dir, buckets=(4, 16))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (8, 16)))
    _ = engine.forward(x)
    lowerings = engine.lowerings

    other = _train(seed=7)
    path = str(tmp_path / "newer")
    export_artifact(path, other)
    engine.reload(path)
    out = engine.forward(x)
    assert engine.lowerings == lowerings, "reload must not recompile"
    ref = ssfn.predict(other.params, jnp.asarray(x), 3)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_reload_rejects_shape_change(artifact_dir, tmp_path):
    engine = ServeEngine(artifact_dir)
    other = _train(hidden=24)
    path = str(tmp_path / "wider")
    export_artifact(path, other)
    with pytest.raises(ValueError, match="mismatch"):
        engine.reload(path)


def test_engine_rejects_wrong_input_dim(artifact_dir):
    engine = ServeEngine(artifact_dir)
    with pytest.raises(ValueError, match="feature rows"):
        engine.forward(np.zeros((9, 4), np.float32))


# ---------------------------------------------------------------------------
# Batching invariance + compile counts
# ---------------------------------------------------------------------------


def test_padded_bucketed_execution_bit_exact(artifact_dir):
    """A J=5 request padded into the 8-bucket returns exactly the first
    5 columns of the same data served as a full 8-batch: zero pad
    columns cannot perturb real ones (column-wise forward)."""
    engine = ServeEngine(artifact_dir, buckets=(8,))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (8, 8)))
    full = np.asarray(engine.forward(x))
    padded = np.asarray(engine.forward(x[:, :5]))
    assert np.array_equal(padded, full[:, :5])
    assert engine.lowerings == 1  # both sizes share the one 8-bucket


def test_single_sample_vs_batch_bit_exact(artifact_dir):
    engine = ServeEngine(artifact_dir, buckets=(8,))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (8, 8)))
    full = np.asarray(engine.forward(x))
    for i in range(8):
        one = np.asarray(engine.forward(x[:, i]))  # (P,) single sample
        assert np.array_equal(one[:, 0], full[:, i])
    assert engine.lowerings == 1


def test_chunked_oversize_batch_bit_exact(artifact_dir):
    """J > max bucket chunks into max-bucket pieces; the concatenated
    result equals serving each chunk alone."""
    engine = ServeEngine(artifact_dir, buckets=(4,))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (8, 10)))
    out = np.asarray(engine.forward(x))
    assert out.shape == (3, 10)
    by_hand = np.concatenate(
        [
            np.asarray(engine.forward(x[:, 0:4])),
            np.asarray(engine.forward(x[:, 4:8])),
            np.asarray(engine.forward(x[:, 8:10])),
        ],
        axis=1,
    )
    assert np.array_equal(out, by_hand)
    assert engine.lowerings == 1  # every chunk pads into the one bucket


def test_two_buckets_cost_exactly_two_lowerings(artifact_dir):
    """N requests spread over 2 buckets lower exactly twice; repeats are
    dispatch-cache hits, never re-traces."""
    engine = ServeEngine(artifact_dir, buckets=(2, 16))
    rng = np.random.default_rng(0)
    for j in (1, 2, 1, 5, 16, 3, 2, 9, 16, 1):
        engine.forward(rng.standard_normal((8, j)).astype(np.float32))
    info = engine.cache_info()
    assert info["lowerings"] == 2, info
    assert sorted(info["buckets"]) == [2, 16]
    assert info["cache_hits"] == 8, info
    # ServeEngine and ConsensusBackend share one normalized cache_info
    # schema — the spmdlint retrace checker reads either.
    from repro.analysis import CACHE_INFO_KEYS, check_cache_info_schema

    assert set(CACHE_INFO_KEYS) <= set(info)
    assert check_cache_info_schema(info, subject="serve-engine") == []
    assert info["entries"] == len(info["keys"]) == 2


def test_distinct_dtypes_get_distinct_executables(artifact_dir):
    engine = ServeEngine(artifact_dir, buckets=(8,))
    x32 = np.zeros((8, 8), np.float32)
    engine.forward(x32)
    engine.forward(x32.astype(np.float16))
    assert engine.lowerings == 2  # same bucket, two wire dtypes


def test_micro_batched_results_bit_exact(artifact_dir):
    """Requests coalesced by the batcher scatter back the same bits as
    serving the concatenated batch directly."""
    engine = ServeEngine(artifact_dir, buckets=(8,))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(6), (8, 8)))
    full = np.asarray(engine.forward(x))
    batcher = MicroBatcher(engine, max_batch=8, max_wait_us=1e9)
    handles = [batcher.submit(x[:, i:i + 1]) for i in range(8)]
    assert all(h.done() for h in handles)  # 8 samples == max_batch: flushed
    got = np.concatenate([np.asarray(h.result()) for h in handles], axis=1)
    assert np.array_equal(got, full)
    assert engine.lowerings == 1


# ---------------------------------------------------------------------------
# Micro-batcher admission
# ---------------------------------------------------------------------------


def test_batcher_max_batch_admission(artifact_dir):
    engine = ServeEngine(artifact_dir, buckets=(4,))
    batcher = MicroBatcher(engine, max_batch=4, max_wait_us=1e9)
    hs = [batcher.submit(np.zeros((8, 1), np.float32)) for _ in range(3)]
    assert not any(h.done() for h in hs)
    assert batcher.pending() == 3
    h4 = batcher.submit(np.zeros((8, 1), np.float32))  # 4th sample: flush
    assert all(h.done() for h in hs) and h4.done()
    assert batcher.pending() == 0
    assert batcher.stats["batches"] == 1


def test_batcher_zero_wait_flushes_every_submit(artifact_dir):
    engine = ServeEngine(artifact_dir, buckets=(4,))
    batcher = MicroBatcher(engine, max_batch=4, max_wait_us=0.0)
    for _ in range(3):
        h = batcher.submit(np.zeros((8, 1), np.float32))
        assert h.done()
    assert batcher.stats["batches"] == 3


def test_batcher_flush_drains_tail(artifact_dir):
    engine = ServeEngine(artifact_dir, buckets=(4,))
    batcher = MicroBatcher(engine, max_batch=4, max_wait_us=1e9)
    h = batcher.submit(np.zeros((8, 1), np.float32))
    assert not h.done()
    with pytest.raises(RuntimeError, match="not served"):
        h.result()
    assert batcher.flush() == 1
    assert h.done() and h.latency_s >= 0.0
    assert batcher.flush() == 0  # empty queue is a no-op


def test_batcher_packs_fifo_and_splits_oversize_queue(artifact_dir):
    engine = ServeEngine(artifact_dir, buckets=(4,))
    batcher = MicroBatcher(engine, max_batch=4, max_wait_us=1e9)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(8), (8, 3)))
    h3 = batcher.submit(x)                              # 3 samples queued
    h2 = batcher.submit(x[:, :2])                       # 5 >= 4: flush
    assert h3.done() and h2.done()
    # 3+2 does not fit one 4-sample batch: FIFO split into [3], [2].
    assert batcher.stats["batches"] == 2
    ref = np.asarray(engine.forward(x))
    assert np.array_equal(np.asarray(h3.result()), ref)
    assert np.array_equal(np.asarray(h2.result()), ref[:, :2])


def test_batcher_rejects_bad_config(artifact_dir):
    engine = ServeEngine(artifact_dir)
    with pytest.raises(ValueError, match="max_batch"):
        MicroBatcher(engine, max_batch=0)
    with pytest.raises(ValueError, match="max_wait_us"):
        MicroBatcher(engine, max_wait_us=-1.0)


# ---------------------------------------------------------------------------
# Feature extractors
# ---------------------------------------------------------------------------


def test_feature_spec_grammar():
    assert parse_features(None) is None
    assert parse_features("identity") is None
    ex = parse_features("rff:64:3")
    assert (ex.kind, ex.dim, ex.seed) == ("rff", 64, 3)
    assert parse_features("relu:32").seed == 0
    for bad in ("rff", "rff:", "rff:0", "rff:8:1:2", "fourier:8"):
        with pytest.raises(ValueError):
            parse_features(bad)


def test_feature_extractor_deterministic_and_column_wise():
    ex1 = parse_features("rff:16:5").materialize(8)
    ex2 = parse_features("rff:16:5").materialize(8)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 6))
    a, b = np.asarray(ex1(x)), np.asarray(ex2(x))
    assert np.array_equal(a, b)
    # Column-wise AT THE SAME PROGRAM SHAPE (the engine's padding
    # invariant): replacing the other columns with zeros cannot perturb
    # column 2.  (A different-shape program may reassociate the matmul,
    # so cross-shape bitwise identity is deliberately NOT claimed.)
    padded = np.zeros_like(np.asarray(x))
    padded[:, 2] = np.asarray(x)[:, 2]
    assert np.array_equal(np.asarray(ex1(jnp.asarray(padded)))[:, 2], a[:, 2])


def test_artifact_with_features_served_on_raw_inputs(tmp_path):
    """Train on frozen rff features, export with the spec recorded, and
    serve RAW inputs — the engine reproduces the featurization, bit-
    identical to applying it by hand before the training-time predict."""
    q, p_raw, d = 3, 8, 12
    ex = parse_features(f"rff:{d}:9").materialize(p_raw)
    xw_raw, tw = _data(jax.random.PRNGKey(11))
    phi = ex(xw_raw.transpose(1, 0, 2).reshape(p_raw, -1))     # (d, m*jm)
    phi_w = phi.reshape(d, 4, 16).transpose(1, 0, 2)
    spec = dssfn.TrainSpec(
        cfg=_cfg(input_dim=d, hidden=2 * q + 20),
        backend="simulated", workers=4,
    )
    result = dssfn.train(spec, phi_w, tw, jax.random.PRNGKey(12))

    path = str(tmp_path / "feat_stack")
    export_artifact(path, result, features=f"rff:{d}:9")
    art = load_artifact(path)
    assert art.features == f"rff:{d}:9"

    engine = ServeEngine(path, buckets=(16,))
    x_raw = np.asarray(xw_raw.transpose(1, 0, 2).reshape(p_raw, -1))[:, :16]
    out = engine.forward(x_raw)
    ref = ssfn.predict(result.params, ex(jnp.asarray(x_raw)), q)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_feature_dim_mismatch_rejected(trained, tmp_path):
    """An extractor whose output dim disagrees with the stack input dim
    fails at first request, not silently."""
    path = str(tmp_path / "bad_feat")
    export_artifact(path, trained, features="rff:9")  # stack expects 8
    engine = ServeEngine(path)
    with pytest.raises(ValueError, match="features"):
        engine.forward(np.zeros((8, 2), np.float32))


# ---------------------------------------------------------------------------
# CLI round-trip (in-process)
# ---------------------------------------------------------------------------


def test_train_export_serve_cli_roundtrip(tmp_path):
    from repro.launch import serve_dssfn, train_dssfn

    art = str(tmp_path / "cli_stack")
    out = train_dssfn.main([
        "--workers", "4", "--backend", "simulated", "--layers", "1",
        "--hidden", "20", "--admm-iters", "20", "--classes", "3",
        "--input-dim", "8", "--train", "64", "--test", "32",
        "--export-artifact", art, "--no-host-mesh",
    ])
    assert out["export"]["path"] == art
    assert is_valid_artifact(art)

    res = serve_dssfn.main([
        "--artifact", art, "--requests", "12", "--request-size", "1",
        "--batch-bucket", "1,4", "--max-wait-us", "0",
    ])
    assert res["requests"] == 12
    assert res["compile"]["lowerings"] <= 2
    assert res["latency_ms"]["p99"] >= res["latency_ms"]["p50"] >= 0.0


def test_serve_cli_refuses_feature_mismatch(tmp_path, trained):
    from repro.launch import serve_dssfn

    path = str(tmp_path / "stack")
    export_artifact(path, trained)
    with pytest.raises(SystemExit, match="refusing to serve"):
        serve_dssfn.main(["--artifact", path, "--features", "rff:8"])
