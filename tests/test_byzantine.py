"""Byzantine-resilient consensus and numerical self-healing.

The robustness-PR acceptance invariants:

- seeded attack injection is pure data inside the cached SPMD program:
  only Byzantine slots corrupt their wire payload, deterministically,
  and every (policy, fault-model) pair lowers exactly once;
- zero-attacker robust policies are bit-identical to plain serial
  ``Gossip`` over the same graph (property-tested over M <= 16);
- one signflip/nanbomb attacker is tolerated with bounded deviation
  from the honest mean, and NaN payloads never reach an aggregate;
- M=8 consensus ADMM with one attacker: ``TrimmedMeanGossip(f=1)``
  lands within 2x of the no-attack baseline's oracle distance while
  the non-robust gossip path fails that bound;
- the guarded Cholesky factors rank-deficient Grams by escalating
  diagonal jitter and reports the jitter level it needed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm, consensus
from repro.core.backend import SimulatedBackend
from repro.core.policy import (
    AsyncGossip,
    ClippedGossip,
    ConsensusContext,
    ExactMean,
    FaultModel,
    Gossip,
    MedianGossip,
    TrimmedMeanGossip,
    parse_policy,
)
from repro.core.topology import Hypercube, Ring, Torus
from repro.testing import given, settings, st


def _mix_once(policy, x):
    """One realized mix over stacked worker values (the backends' vmap
    SPMD semantics)."""
    ctx = ConsensusContext("workers", x.shape[0])

    def body(xi):
        state = policy.init_state(xi, ctx)
        y, _ = policy.mix(xi, state, ctx)
        return y

    return jax.vmap(body, axis_name="workers")(x)


def _problem(key, n=16, q=3, j=160, m=8):
    ky, kt = jax.random.split(key)
    y = jax.random.normal(ky, (n, j))
    t = jax.random.normal(kt, (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)
    return yw, tw


# ------------------------------------------------------------------
# Attack injection: FaultModel byzantine/attack surface
# ------------------------------------------------------------------

def test_attack_spec_validation():
    for spec in ("signflip", "scale:10", "noise:0.5", "nanbomb", "replay:2"):
        FaultModel(byzantine=(0,), attack=spec)  # parses
    with pytest.raises(ValueError, match="unknown attack"):
        FaultModel(attack="meteor")
    with pytest.raises(ValueError, match="takes no"):
        FaultModel(attack="signflip:2")
    with pytest.raises(ValueError, match="needs an argument"):
        FaultModel(attack="scale")
    with pytest.raises(ValueError, match="replay depth"):
        FaultModel(attack="replay:0")
    with pytest.raises(ValueError, match="every worker Byzantine"):
        FaultModel(byzantine=(0, 1, 2, 3)).validate(4)


def test_byzantine_arms_fault_model():
    assert FaultModel().is_null
    assert FaultModel(attack="nanbomb").is_null  # attack without attackers
    fm = FaultModel(byzantine=(2,), attack="nanbomb")
    assert not fm.is_null
    assert fm.attack_kind == "nanbomb"
    assert fm.replay_depth == 0
    assert FaultModel(byzantine=(1,), attack="replay:3").replay_depth == 3


def test_corrupted_payload_kinds():
    fm = lambda a: FaultModel(byzantine=(0,), attack=a)  # noqa: E731
    x = jnp.arange(6.0).reshape(2, 3)
    np.testing.assert_array_equal(
        fm("signflip").corrupted_payload(x, iteration=0, round_idx=0), -x
    )
    np.testing.assert_array_equal(
        fm("scale:10").corrupted_payload(x, iteration=0, round_idx=0), 10 * x
    )
    assert bool(
        jnp.all(
            jnp.isnan(fm("nanbomb").corrupted_payload(x, iteration=0, round_idx=0))
        )
    )
    buf = jnp.full_like(x, 7.0)
    np.testing.assert_array_equal(
        fm("replay:1").corrupted_payload(x, iteration=0, round_idx=0, replay=buf),
        buf,
    )
    with pytest.raises(ValueError, match="replay attack needs"):
        fm("replay:1").corrupted_payload(x, iteration=0, round_idx=0)
    # noise is seeded: same (iteration, round) -> same draw, new round ->
    # new draw, and every worker computes the identical corruption.
    n1 = fm("noise:0.5").corrupted_payload(x, iteration=3, round_idx=1)
    n2 = fm("noise:0.5").corrupted_payload(x, iteration=3, round_idx=1)
    n3 = fm("noise:0.5").corrupted_payload(x, iteration=3, round_idx=2)
    assert jnp.array_equal(n1, n2)
    assert not jnp.array_equal(n1, n3)


def test_transmit_for_corrupts_only_byzantine_slots():
    fm = FaultModel(byzantine=(1, 3), attack="signflip")
    x = jnp.ones((4,))
    for w in range(5):
        tx = fm.transmit_for(
            x, worker_index=jnp.asarray(w), num_workers=5,
            iteration=jnp.zeros((), jnp.int32), round_idx=0,
        )
        expect = -x if w in (1, 3) else x
        np.testing.assert_array_equal(np.asarray(tx), np.asarray(expect))


def test_nanbomb_never_leaks_into_honest_transmissions():
    """The corrupted payload is selected with jnp.where on a scalar
    predicate — an honest worker's wire value stays finite even though
    the NaN payload is materialized in-program."""
    fm = FaultModel(byzantine=(2,), attack="nanbomb")
    tx = fm.transmit_for(
        jnp.ones((3,)), worker_index=jnp.asarray(0), num_workers=4,
        iteration=jnp.zeros((), jnp.int32), round_idx=0,
    )
    assert bool(jnp.all(jnp.isfinite(tx)))


# ------------------------------------------------------------------
# Zero-attacker bit-identity (property over M <= 16)
# ------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([4, 8, 16]), kind=st.sampled_from(
    ["trimmed", "median", "clipped"]
))
def test_robust_policies_bit_identical_to_gossip_when_clean(m, kind):
    x = jax.random.normal(jax.random.PRNGKey(m), (m, 5))
    topo = Ring(1)  # valid for every sampled M
    make = {
        "trimmed": lambda: TrimmedMeanGossip(f=1, rounds=3, topology=topo),
        "median": lambda: MedianGossip(rounds=3, topology=topo),
        "clipped": lambda: ClippedGossip(tau=0.5, rounds=3, topology=topo),
    }[kind]
    out = _mix_once(make(), x)
    ref = _mix_once(Gossip(rounds=3, topology=topo, compress=False), x)
    assert jnp.array_equal(out, ref), kind


# ------------------------------------------------------------------
# Attack tolerance: bounded deviation, NaN screening
# ------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(m=st.sampled_from([8, 16]), attack=st.sampled_from(
    ["signflip", "nanbomb"]
))
def test_robust_mix_tolerates_one_attacker_with_bounded_deviation(m, attack):
    """Concentrated honest values + one attacker: the robust mix stays
    inside the honest hull (deviation bounded by the honest spread),
    where plain mixing is thrown far outside it (or NaN-poisoned)."""
    spread = 0.01
    honest = 2.0 + spread * jax.random.normal(jax.random.PRNGKey(m), (m, 4))
    fm = FaultModel(byzantine=(3,), attack=attack)
    hmean = jnp.delete(honest, 3, axis=0).mean(axis=0)
    for pol in (
        TrimmedMeanGossip(f=1, rounds=2, topology=Hypercube(), faults=fm),
        MedianGossip(rounds=2, topology=Hypercube(), faults=fm),
        ClippedGossip(tau=5 * spread, rounds=2, topology=Hypercube(), faults=fm),
    ):
        out = _mix_once(pol, honest)
        assert bool(jnp.all(jnp.isfinite(out))), type(pol).__name__
        dev = float(jnp.max(jnp.abs(out - hmean[None, :])))
        assert dev < 10 * spread, (type(pol).__name__, dev)
    vuln = _mix_once(
        AsyncGossip(rounds=2, topology=Hypercube(), faults=fm), honest
    )
    if attack == "nanbomb":
        assert not bool(jnp.all(jnp.isfinite(vuln)))
    else:
        assert float(jnp.max(jnp.abs(vuln - hmean[None, :]))) > 10 * spread


def test_nan_screen_reroutes_link_weight_to_diagonal():
    """A nanbombed link degrades into the PR-6 dead-link reroute: the
    receiver's mix equals the faulty-gossip step with that link down."""
    m = 8
    x = jax.random.normal(jax.random.PRNGKey(0), (m, 3))
    fm = FaultModel(byzantine=(0,), attack="nanbomb")
    out = _mix_once(
        TrimmedMeanGossip(f=1, rounds=1, topology=Ring(1), faults=fm), x
    )
    assert bool(jnp.all(jnp.isfinite(out)))
    # Ring(1) neighborhoods are {i-1, i, i+1}; with worker 0's payload
    # rerouted, worker 1 averages (x0->x1 replaced by x1).
    np.testing.assert_allclose(
        np.asarray(out[1]),
        np.asarray((x[1] + x[1] + x[2]) / 3.0),
        rtol=1e-6,
    )
    # Workers not adjacent to the attacker mix exactly.
    np.testing.assert_allclose(
        np.asarray(out[4]),
        np.asarray((x[3] + x[4] + x[5]) / 3.0),
        rtol=1e-6,
    )


# ------------------------------------------------------------------
# End-to-end ADMM acceptance: robust converges, plain fails
# ------------------------------------------------------------------

def test_trimmed_mean_admm_within_2x_of_no_attack_oracle_rel():
    """M=8, one attacker: TrimmedMeanGossip(f=1) reaches an oracle
    distance within 2x of the no-attack baseline — measured against the
    honest-data oracle, since a Byzantine worker's shard is unlearnable
    (every payload it emits is corrupted) — while the non-robust gossip
    path fails the same bound on both attacks."""
    m = 8
    yw, tw = _problem(jax.random.PRNGKey(4), m=m)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=40)
    oracle = admm.admm_ridge_consensus(
        yw, tw, backend=SimulatedBackend(m), policy=ExactMean(), **kw
    )
    keep = np.array([i for i in range(m) if i != 3])
    oracle_honest = admm.admm_ridge_consensus(
        yw[keep], tw[keep], backend=SimulatedBackend(m - 1),
        policy=ExactMean(), **kw
    )

    def rel(res, ref):
        return float(
            jnp.linalg.norm(res.o_star - ref.o_star)
            / jnp.linalg.norm(ref.o_star)
        )

    topo = Hypercube()
    baseline = rel(
        admm.admm_ridge_consensus(
            yw, tw, backend=SimulatedBackend(m),
            policy=TrimmedMeanGossip(f=1, rounds=3, topology=topo), **kw
        ),
        oracle,
    )
    bound = 2.0 * baseline
    for attack in ("signflip", "nanbomb"):
        fm = FaultModel(byzantine=(3,), attack=attack)
        robust = admm.admm_ridge_consensus(
            yw, tw, backend=SimulatedBackend(m),
            policy=TrimmedMeanGossip(f=1, rounds=3, topology=topo, faults=fm),
            **kw,
        )
        r = rel(robust, oracle_honest)
        assert np.isfinite(r) and r <= bound, (attack, r, bound)
        vuln = admm.admm_ridge_consensus(
            yw, tw, backend=SimulatedBackend(m),
            policy=AsyncGossip(rounds=3, topology=topo, faults=fm), **kw
        )
        rv = rel(vuln, oracle_honest)
        assert not np.isfinite(rv) or rv > bound, (attack, rv, bound)


# ------------------------------------------------------------------
# Compile-count: (policy, fault-model) pairs lower exactly once
# ------------------------------------------------------------------

def test_byzantine_fault_models_ride_executable_cache_key():
    m = 8
    yw, tw = _problem(jax.random.PRNGKey(11), m=m)
    backend = SimulatedBackend(m)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=10, backend=backend)
    pols = [
        TrimmedMeanGossip(f=1, rounds=2, topology=Hypercube()),
        TrimmedMeanGossip(
            f=1, rounds=2, topology=Hypercube(),
            faults=FaultModel(byzantine=(3,), attack="signflip"),
        ),
        TrimmedMeanGossip(
            f=1, rounds=2, topology=Hypercube(),
            faults=FaultModel(byzantine=(3,), attack="nanbomb"),
        ),
        MedianGossip(
            rounds=2, topology=Hypercube(),
            faults=FaultModel(byzantine=(3,), attack="scale:10"),
        ),
        ClippedGossip(
            tau=0.5, rounds=2, topology=Hypercube(),
            faults=FaultModel(byzantine=(3,), attack="noise:0.5"),
        ),
    ]
    for pol in pols:
        admm.admm_ridge_consensus(yw, tw, policy=pol, **kw)
    assert backend.lowerings == len(pols), backend.cache_info()
    # Second sweep over every (policy, fault-model) pair: pure cache hits.
    for pol in pols:
        admm.admm_ridge_consensus(yw, tw, policy=pol, **kw)
    assert backend.lowerings == len(pols), backend.cache_info()
    assert backend.cache_hits >= len(pols)


def test_replay_attack_threads_transmit_history():
    """replay:d transmits the payload from d mixes ago (zeros before the
    window fills) — the state threads through repeated mix calls."""
    m = 4
    fm = FaultModel(byzantine=(1,), attack="replay:1")
    pol = TrimmedMeanGossip(f=1, rounds=1, topology=Ring(1), faults=fm)
    ctx = ConsensusContext("workers", m)
    xs = [
        jax.random.normal(jax.random.PRNGKey(9 + i), (m, 3)) for i in range(2)
    ]

    def body(x1, x2):
        state = pol.init_state(x1, ctx)
        y1, state = pol.mix(x1, state, ctx)
        y2, state = pol.mix(x2, state, ctx)
        return y1, y2

    y1, y2 = jax.vmap(body, axis_name="workers")(*xs)
    assert bool(jnp.all(jnp.isfinite(y1)))
    assert bool(jnp.all(jnp.isfinite(y2)))


# ------------------------------------------------------------------
# Guarded Cholesky: self-healing factorization
# ------------------------------------------------------------------

def test_guarded_cholesky_clean_gram_reports_zero_jitter():
    y = jax.random.normal(jax.random.PRNGKey(0), (6, 40))
    g = y @ y.T + 0.1 * jnp.eye(6)
    chol, jitter = admm.guarded_cholesky(g)
    assert int(jitter) == 0
    np.testing.assert_allclose(
        np.asarray(chol @ chol.T), np.asarray(g), atol=1e-4
    )
    # Matches the unguarded factorization bit for bit on clean input.
    assert jnp.array_equal(chol, jnp.linalg.cholesky(g))


def test_guarded_cholesky_recovers_rank_deficient_gram():
    """A rank-deficient Gram (duplicated features, mu -> inf limit) makes
    plain Cholesky return NaN; the guard escalates diagonal jitter until
    the factorization goes through and reports the level it needed."""
    y = jax.random.normal(jax.random.PRNGKey(1), (4, 20))
    y = jnp.concatenate([y, y], axis=0)  # rank 4 of 8
    g = y @ y.T
    assert not bool(jnp.all(jnp.isfinite(jnp.linalg.cholesky(g))))
    chol, jitter = admm.guarded_cholesky(g)
    assert bool(jnp.all(jnp.isfinite(chol)))
    assert int(jitter) >= 1
    # Cholesky is backward stable: the factor reconstructs the jittered
    # Gram it actually factored (eps at the reported escalation level).
    scale = float(jnp.mean(jnp.abs(jnp.diagonal(g))))
    eps = scale * 1e-8 * 10.0 ** (int(jitter) - 1)
    rel = jnp.linalg.norm(chol @ chol.T - (g + eps * jnp.eye(8)))
    assert float(rel) < 1e-4 * jnp.linalg.norm(g)


def test_guarded_cholesky_traces_under_vmap():
    y = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 30))
    grams = jnp.einsum("mij,mkj->mik", y, y) + 0.1 * jnp.eye(5)
    chol, jitter = jax.vmap(admm.guarded_cholesky)(grams)
    assert chol.shape == (3, 5, 5)
    assert jitter.shape == (3,)
    assert bool(jnp.all(jitter == 0))


def test_admm_result_reports_jitter_per_worker():
    yw, tw = _problem(jax.random.PRNGKey(5), m=4)
    res = admm.admm_ridge_consensus(
        yw, tw, backend=SimulatedBackend(4), mu=1e-2, eps_radius=6.0,
        num_iters=5,
    )
    assert res.jitter is not None
    assert res.jitter.shape == (4,)
    assert bool(jnp.all(res.jitter == 0))  # well-conditioned problem


# ------------------------------------------------------------------
# Spec grammar: robust policy round-trips
# ------------------------------------------------------------------

def test_parse_robust_specs_round_trip():
    cases = {
        "trimmed": TrimmedMeanGossip(),
        "trimmed:f=2:rounds=3": TrimmedMeanGossip(f=2, rounds=3),
        "trimmed:f=1:attack=signflip@torus:2x4": TrimmedMeanGossip(
            f=1, topology=Torus(2, 4),
            faults=FaultModel(byzantine=(0,), attack="signflip"),
        ),
        "median": MedianGossip(),
        "median:byz=3:attack=nanbomb@hypercube": MedianGossip(
            topology=Hypercube(),
            faults=FaultModel(byzantine=(3,), attack="nanbomb"),
        ),
        "median:attack=noise:0.5": MedianGossip(
            faults=FaultModel(byzantine=(0,), attack="noise:0.5"),
        ),
        "clipped:0.5": ClippedGossip(tau=0.5),
        "clipped:tau=0.25:rounds=2": ClippedGossip(tau=0.25, rounds=2),
        "clipped:tau=0.5:byz=1+2:attack=replay:3": ClippedGossip(
            tau=0.5, faults=FaultModel(byzantine=(1, 2), attack="replay:3"),
        ),
        "trimmed:attack=scale:10:rounds=2": TrimmedMeanGossip(
            rounds=2, faults=FaultModel(byzantine=(0,), attack="scale:10"),
        ),
        "trimmed:wire=bf16": TrimmedMeanGossip(wire_dtype="bfloat16"),
    }
    for spec, expected in cases.items():
        assert parse_policy(spec) == expected, spec


def test_parse_robust_spec_errors():
    with pytest.raises(ValueError, match="either positionally"):
        parse_policy("clipped:0.5:tau=0.7")
    with pytest.raises(ValueError, match="unknown attack"):
        parse_policy("trimmed:attack=meteor")
    with pytest.raises(ValueError, match="f >= 1"):
        parse_policy("trimmed:f=0")
    with pytest.raises(ValueError, match="tau must be > 0"):
        parse_policy("clipped:0")


def test_unknown_policy_error_lists_full_grammar():
    with pytest.raises(ValueError) as ei:
        parse_policy("bogus")
    msg = str(ei.value)
    for token in (
        "exact", "gossip", "quantized", "lossy", "stale", "async",
        "trimmed", "median", "clipped", "signflip", "nanbomb", "replay",
        "torus:RxC", "hypercube", "geometric", "ring", "full",
    ):
        assert token in msg, token


def test_robust_policy_validation_errors():
    with pytest.raises(ValueError, match="uniform"):
        # geometric graphs compile to weighted Metropolis hops
        from repro.core.topology import RandomGeometric

        TrimmedMeanGossip(
            f=1, topology=RandomGeometric(radius=0.9, seed=0)
        ).validate(8)
    with pytest.raises(ValueError, match="neighborhood"):
        TrimmedMeanGossip(f=2, topology=Ring(1)).validate(8)
    with pytest.raises(ValueError, match="stragglers"):
        MedianGossip(
            topology=Ring(1), faults=FaultModel(stragglers=(1,))
        ).validate(8)


def test_robust_policies_account_eq15_wire():
    pol = TrimmedMeanGossip(f=1, rounds=2, topology=Hypercube())
    ref = Gossip(rounds=2, topology=Hypercube(), compress=False)
    kw = dict(scalars=100, num_consensus=10, num_workers=8)
    assert pol.exchanges_for(8) == ref.exchanges_for(8)
    assert pol.comm_scalars(**kw) == ref.comm_scalars(**kw)
    bf = TrimmedMeanGossip(
        f=1, rounds=2, topology=Hypercube(), wire_dtype="bfloat16"
    )
    assert bf.wire_bytes(**kw) == pol.wire_bytes(**kw) // 2
