"""Multi-device numerical tests (8 fake host devices via subprocess —
XLA_FLAGS must be set before jax initializes, so these run out of process).

Covers paths the single-device suite cannot execute numerically:
- the manual shard_map MoE (combine-before-psum) vs the plain path,
- ring-gossip consensus via lax.ppermute vs the dense-H reference,
- the distributed dSSFN ADMM solve on a real (2, 4) mesh,
- MeshBackend vs SimulatedBackend vs centralized-oracle parity on an
  M=8 ``workers`` mesh (the ConsensusBackend acceptance test).
"""
import os
import subprocess
import sys
import textwrap


REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str) -> str:
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        """
    ) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": REPO_SRC},
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_shardmap_matches_plain():
    out = run_subprocess("""
    from repro.sharding.rules import AxisRules, use_rules
    from repro.nn.moe import moe_ffn, _moe_core

    b, s, d, f, e, k = 4, 32, 16, 32, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, e))
    wg = jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, f, d)) / np.sqrt(f)

    ref, ref_stats = _moe_core(x, router, wg, wu, wd, top_k=k,
                               capacity_factor=float(e), constrain=False)
    rules = AxisRules(mesh=mesh, data_axes=("data",), model_axis="model")
    with mesh, use_rules(rules):
        got, stats = jax.jit(lambda *a: moe_ffn(*a, top_k=k,
                                                capacity_factor=float(e)))(
            x, router, wg, wu, wd)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 1e-4, err
    assert abs(float(stats.aux_loss) - float(ref_stats.aux_loss)) < 1e-4
    # gradients agree too
    loss_plain = lambda w: jnp.sum(_moe_core(x, router, w, wu, wd, top_k=k,
        capacity_factor=float(e), constrain=False)[0] ** 2)
    with mesh, use_rules(rules):
        loss_sm = lambda w: jnp.sum(moe_ffn(x, router, w, wu, wd, top_k=k,
            capacity_factor=float(e))[0] ** 2)
        g_sm = jax.jit(jax.grad(loss_sm))(wg)
    g_ref = jax.grad(loss_plain)(wg)
    gerr = float(jnp.max(jnp.abs(g_sm - g_ref)) / (jnp.max(jnp.abs(g_ref)) + 1e-9))
    assert gerr < 1e-3, gerr
    print("MOE_OK", err, gerr)
    """)
    assert "MOE_OK" in out


def test_ring_gossip_ppermute_matches_dense():
    out = run_subprocess("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from repro.core import consensus, topology

    m, degree, rounds = 8, 2, 5
    x = jax.random.normal(jax.random.PRNGKey(0), (m, 6))
    h = topology.circular_mixing_matrix(m, degree)
    want = consensus.gossip_average(x, h, rounds)

    ring_mesh = make_mesh_compat((8,), ("w",))
    fn = shard_map(
        partial(consensus.ring_gossip_average, axis_name="w", degree=degree,
                num_nodes=m, num_rounds=rounds),
        mesh=ring_mesh, in_specs=P("w"), out_specs=P("w"), check_rep=False)
    with ring_mesh:
        got = jax.jit(fn)(x)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, err
    print("GOSSIP_OK", err)
    """)
    assert "GOSSIP_OK" in out


def test_mesh_backend_matches_simulated_and_oracle():
    """The tentpole guarantee: the SAME worker program under MeshBackend
    (shard_map, device-local shards) and SimulatedBackend (vmap axis)
    produces the same dSSFN training run, and both match the centralized
    oracle — in exact AND ring-gossip consensus modes."""
    out = run_subprocess("""
    from repro.core import admm, layerwise, ssfn
    from repro.core.backend import MeshBackend, SimulatedBackend
    from repro.core.policy import QuantizedGossip, RingGossip, StaleMixing
    from repro.launch.mesh import make_worker_mesh

    m, n, q, j = 8, 16, 3, 256
    mesh = make_worker_mesh(m)
    y = jax.random.normal(jax.random.PRNGKey(0), (n, j))
    t = jax.random.normal(jax.random.PRNGKey(1), (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)

    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=300)
    sim = admm.admm_ridge_consensus(yw, tw, backend=SimulatedBackend(m), **kw)
    msh = admm.admm_ridge_consensus(yw, tw, backend=MeshBackend(mesh), **kw)
    rel_pair = float(jnp.linalg.norm(sim.o_star - msh.o_star)
                     / jnp.linalg.norm(sim.o_star))
    assert rel_pair < 1e-4, rel_pair
    rel_obj = float(jnp.abs(sim.trace.objective[-1] - msh.trace.objective[-1])
                    / sim.trace.objective[-1])
    assert rel_obj < 1e-4, rel_obj
    oracle = admm.exact_constrained_ridge(y, t, eps_radius=6.0)
    rel_oracle = float(jnp.linalg.norm(msh.o_star - oracle) / jnp.linalg.norm(oracle))
    assert rel_oracle < 1e-3, rel_oracle

    gpol = RingGossip(rounds=6, degree=2)
    simg = admm.admm_ridge_consensus(
        yw, tw, backend=SimulatedBackend(m, policy=gpol), **kw)
    mshg = admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(mesh, policy=gpol), **kw)
    rel_g = float(jnp.linalg.norm(simg.o_star - mshg.o_star)
                  / jnp.linalg.norm(simg.o_star))
    assert rel_g < 1e-4, rel_g

    # The stranded-in-robust.py policies now run on the REAL mesh: the
    # same stateful policy program (quantizer keys / staleness buffers in
    # the scan carry) under vmap and shard_map.  StaleMixing is
    # deterministic -> tight sim-vs-mesh parity; QuantizedGossip's
    # stochastic rounding sits on bit-level thresholds, so runtime
    # reduction-order ulps flip individual draws — assert statistical
    # closeness and oracle proximity instead.
    for pol, pair_tol in ((QuantizedGossip(bits=8), 2e-2), (StaleMixing(2), 1e-4)):
        simp = admm.admm_ridge_consensus(
            yw, tw, backend=SimulatedBackend(m), policy=pol, **kw)
        mshp = admm.admm_ridge_consensus(
            yw, tw, backend=MeshBackend(mesh), policy=pol, **kw)
        rel_p = float(jnp.linalg.norm(simp.o_star - mshp.o_star)
                      / jnp.linalg.norm(simp.o_star))
        assert rel_p < pair_tol, (pol, rel_p)
        rel_o = float(jnp.linalg.norm(mshp.o_star - oracle)
                      / jnp.linalg.norm(oracle))
        assert rel_o < 5e-2, (pol, rel_o)

    # Full layer-wise training: shards stay device-local end to end.
    cfg = ssfn.SSFNConfig(input_dim=10, num_classes=3, num_layers=2,
                          hidden=24, admm_iters=60)
    kx, kt, kinit = jax.random.split(jax.random.PRNGKey(2), 3)
    xw = jax.random.normal(kx, (m, 10, 24))
    labels = jax.random.randint(kt, (m, 24), 0, 3)
    tw2 = jax.nn.one_hot(labels, 3).transpose(0, 2, 1)
    ps, logs = layerwise.train_decentralized_ssfn(
        xw, tw2, cfg, kinit, backend=SimulatedBackend(m))
    pm, logm = layerwise.train_decentralized_ssfn(
        xw, tw2, cfg, kinit, backend=MeshBackend(mesh))
    rel_train = abs(logs.layer_costs[-1] - logm.layer_costs[-1]) / abs(
        logs.layer_costs[-1])
    assert rel_train < 1e-4, rel_train
    print("MESHBACKEND_OK", rel_pair, rel_g, rel_train)
    """)
    assert "MESHBACKEND_OK" in out


def test_topology_gossip_mesh_parity_on_8_devices():
    """The topology-seam acceptance test: non-ring mixing graphs (torus,
    hypercube, time-varying, Birkhoff-compiled geometric) run their
    exchange schedules as real collective_permutes on an M=8 ``workers``
    mesh, match the vmap simulation, match the dense H^B reference, and
    RingGossip stays bit-identical to the raw PR-3 ring hops."""
    out = run_subprocess("""
    from repro.core import admm, consensus
    from repro.core.backend import MeshBackend, SimulatedBackend
    from repro.core.policy import Gossip, RingGossip
    from repro.core.topology import (
        Hypercube, RandomGeometric, Ring, TimeVarying, Torus)
    from repro.launch.mesh import make_worker_mesh

    m, n, q, j = 8, 16, 3, 256
    wmesh = make_worker_mesh(m)
    y = jax.random.normal(jax.random.PRNGKey(0), (n, j))
    t = jax.random.normal(jax.random.PRNGKey(1), (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=300)
    oracle = admm.exact_constrained_ridge(y, t, eps_radius=6.0)

    # Raw mixing parity on the mesh: schedule hops == dense H^B.
    x = jax.random.normal(jax.random.PRNGKey(2), (m, 4, 6))
    topos = (Torus(2, 4), Hypercube(), TimeVarying((Ring(1), Hypercube())),
             RandomGeometric(radius=0.5, seed=1))
    for topo in topos:
        rounds = 4
        pol = Gossip(rounds=rounds, topology=topo)
        mesh_be = MeshBackend(wmesh, policy=pol)
        got = mesh_be.run(mesh_be.consensus_mean, x)
        cycle = topo.cycle()  # round b mixes with cycle[b % L]'s H
        want = x
        for b in range(rounds):
            want = consensus.gossip_average(
                want, cycle[b % len(cycle)].mixing_matrix(m), 1)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, (topo, err)

    # Full ADMM solves: sim-vs-mesh parity + oracle proximity per graph.
    for topo in (Torus(2, 4), Hypercube()):
        pol = Gossip(rounds=6, topology=topo)
        sim = admm.admm_ridge_consensus(
            yw, tw, backend=SimulatedBackend(m, policy=pol), **kw)
        msh = admm.admm_ridge_consensus(
            yw, tw, backend=MeshBackend(wmesh, policy=pol), **kw)
        rel = float(jnp.linalg.norm(sim.o_star - msh.o_star)
                    / jnp.linalg.norm(sim.o_star))
        assert rel < 1e-4, (topo, rel)
        rel_o = float(jnp.linalg.norm(msh.o_star - oracle)
                      / jnp.linalg.norm(oracle))
        assert rel_o < 5e-2, (topo, rel_o)

    # RingGossip(compress=False) == raw ring hops, bit for bit, on the
    # real mesh; the default compressed H^B mix matches to f32 tolerance.
    ring_be = MeshBackend(
        wmesh, policy=RingGossip(rounds=5, degree=2, compress=False))
    got = ring_be.run(ring_be.consensus_mean, x)
    def raw(v):
        return consensus.ring_gossip_average(
            v, ring_be.axis_name, degree=2, num_nodes=m, num_rounds=5)
    want = ring_be.run(raw, x, key="raw-ring")
    assert jnp.array_equal(got, want)
    comp_be = MeshBackend(wmesh, policy=RingGossip(rounds=5, degree=2))
    got_c = comp_be.run(comp_be.consensus_mean, x)
    assert float(jnp.max(jnp.abs(got_c - want))) < 1e-5
    print("TOPOLOGY8_OK")
    """)
    assert "TOPOLOGY8_OK" in out


def test_compressed_gossip_and_hot_path_on_8_devices():
    """The wire-efficiency acceptance tests on a real 8-worker mesh:

    - compressed ring & torus gossip solves match their serial-schedule
      twins (same H^B mixing, one mix instead of B rounds);
    - trace_every=0 keeps the final iterate bit-identical (ExactMean)
      while the lowered program's collectives reduce to EXACTLY the
      policy's own exchanges (no psum/pmax trio, no cerr probe) —
      asserted via the backend lowering stats / HLO collective counts.
    """
    out = run_subprocess("""
    from repro.core import admm
    from repro.core.backend import MeshBackend
    from repro.core.policy import ExactMean, Gossip, RingGossip
    from repro.core.topology import Ring, Torus
    from repro.launch.mesh import make_worker_mesh

    m, n, q, j = 8, 16, 3, 256
    wmesh = make_worker_mesh(m)
    y = jax.random.normal(jax.random.PRNGKey(0), (n, j))
    t = jax.random.normal(jax.random.PRNGKey(1), (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=40)

    # Compressed vs serial schedule parity, full ADMM solve, per graph.
    for topo in (Ring(2), Torus(2, 4)):
        comp = admm.admm_ridge_consensus(
            yw, tw, backend=MeshBackend(
                wmesh, policy=Gossip(rounds=4, topology=topo)), **kw)
        serial = admm.admm_ridge_consensus(
            yw, tw, backend=MeshBackend(
                wmesh, policy=Gossip(rounds=4, topology=topo,
                                     compress=False)), **kw)
        rel = float(jnp.linalg.norm(comp.o_star - serial.o_star)
                    / jnp.linalg.norm(serial.o_star))
        assert rel < 1e-5, (topo, rel)

    # Hot path: bit-identical o_star, collective-free lowering.  The
    # expected counts come from the spmdlint wire model (repro.analysis)
    # — the same model `lint_dssfn --all-grammar` checks in CI.
    from repro import analysis

    K = 10
    z0 = jnp.zeros((q, n))
    def probe(policy, trace_every):
        backend = MeshBackend(wmesh, policy=policy)
        def worker(y_m, t_m, z0r):
            a, chol, _ = admm._worker_stats_local(y_m, t_m, 1e-2, False)
            return admm.worker_admm_iterations(
                backend, a, chol, y_m, t_m, z0r, mu=1e-2, eps_radius=6.0,
                num_iters=K, policy=policy, trace_every=trace_every)
        return backend.lowering_stats(
            worker, yw, tw, replicated=(z0,),
            key=("probe", trace_every), policy=policy)

    def expect_hot(policy):
        per_mix = analysis.expected_mix_collectives(policy, m)
        return {op: K * c for op, c in per_mix.items()}

    pol = RingGossip(rounds=4, degree=2)
    hot = probe(pol, 0)["collective_counts"]
    traced = probe(pol, 1)["collective_counts"]
    # trace_every=0: ONLY the policy's ppermutes — K mixes x hops each,
    # and not a single reduction collective.
    assert hot == expect_hot(pol), (hot, expect_hot(pol))
    # trace_every=1 adds the psum obj + psum primal + cerr pmean/pmax.
    assert traced.get("all-reduce", 0) == 4 * K, traced

    ex_hot = probe(ExactMean(), 0)["collective_counts"]
    assert ex_hot == expect_hot(ExactMean()), ex_hot

    # The full wire contract (counts, payload widths, eq.-15 declaration
    # arithmetic) holds for both policies on this mesh.
    for p in (pol, ExactMean()):
        found = analysis.check_wire_contract(
            p, MeshBackend(wmesh, policy=p), num_iters=K, subject=str(p))
        assert found == [], [f.render() for f in found]

    # And the final iterate is bit-identical with traces off.
    be = MeshBackend(wmesh)
    kw10 = dict(mu=1e-2, eps_radius=6.0, num_iters=K, backend=be)
    a = admm.admm_ridge_consensus(yw, tw, **kw10)
    b = admm.admm_ridge_consensus(yw, tw, trace_every=0, **kw10)
    assert jnp.array_equal(a.o_star, b.o_star)
    assert b.trace is None
    print("WIRE8_OK")
    """)
    assert "WIRE8_OK" in out


def test_layer_engine_on_8_devices():
    """Compile-once layer engine on a real M=8 ``workers`` mesh: kernel-path
    parity (use_kernels=True vs einsum, exact AND gossip consensus) and the
    compile-count invariant (lowerings == distinct layer shapes)."""
    out = run_subprocess("""
    import dataclasses
    from repro.core import layerwise, ssfn
    from repro.core.backend import MeshBackend, SimulatedBackend
    from repro.core.policy import ExactMean, RingGossip
    from repro.launch.mesh import make_worker_mesh

    m = 8
    wmesh = make_worker_mesh(m)
    cfg = ssfn.SSFNConfig(input_dim=128, num_classes=3, num_layers=2,
                          hidden=128, admm_iters=15)
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    kx, kt, kinit = jax.random.split(jax.random.PRNGKey(0), 3)
    xw = jax.random.normal(kx, (m, 128, 128))
    labels = jax.random.randint(kt, (m, 128), 0, 3)
    tw = jax.nn.one_hot(labels, 3).transpose(0, 2, 1)

    for pol in (ExactMean(), RingGossip(rounds=6, degree=2)):
        mesh_be = MeshBackend(wmesh, policy=pol)
        pk, _ = layerwise.train_decentralized_ssfn(
            xw, tw, cfg_k, kinit, backend=mesh_be)
        pr, _ = layerwise.train_decentralized_ssfn(
            xw, tw, cfg, kinit, backend=MeshBackend(wmesh, policy=pol))
        ps, _ = layerwise.train_decentralized_ssfn(
            xw, tw, cfg_k, kinit, backend=SimulatedBackend(m, policy=pol))
        for a, b in zip(pk.o, pr.o):   # kernels == einsum on the mesh
            rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
            assert rel < 1e-6, (pol, rel)
        for a, b in zip(pk.o, ps.o):   # sim == mesh through the engine
            rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
            assert rel < 1e-4, (pol, rel)
        # 3 layer solves, 3 distinct programs even though l=1 and l=2
        # share W shape (128,128) here: l=0 has no W, l=1 must not donate
        # the caller-reachable Y, l=2 donates the engine-owned carry.
        # The win shows from l=3 on (none here) and on repeat trains:
        assert mesh_be.lowerings == 3, mesh_be.cache_info()
        layerwise.train_decentralized_ssfn(
            xw, tw, cfg_k, kinit, backend=mesh_be)
        assert mesh_be.lowerings == 3, mesh_be.cache_info()  # fully cached
    print("ENGINE8_OK")
    """)
    assert "ENGINE8_OK" in out


def test_async_faults_and_elastic_resume_on_8_devices():
    """The elastic-consensus acceptance tests on a real M=8 mesh:

    - a disabled fault model leaves the lowered hot path UNCHANGED —
      AsyncGossip's collective counts equal serial Gossip's, and the
      solve is bit-identical;
    - under drop=0.2 the whole training run is deterministic (two mesh
      runs bit-equal), matches the vmap simulation, and compiles each
      layer shape exactly once (faults run INSIDE the cached program —
      no per-iteration retraces);
    - a mid-run checkpoint + kill + resume reproduces the uninterrupted
      run's final iterate on the mesh backend.
    """
    out = run_subprocess("""
    import tempfile
    from repro.core import admm, layerwise, ssfn
    from repro.core.backend import MeshBackend, SimulatedBackend
    from repro.core.policy import AsyncGossip, FaultModel, Gossip
    from repro.core.topology import Hypercube
    from repro.launch.mesh import make_worker_mesh

    m, n, q, j = 8, 16, 3, 256
    wmesh = make_worker_mesh(m)
    y = jax.random.normal(jax.random.PRNGKey(0), (n, j))
    t = jax.random.normal(jax.random.PRNGKey(1), (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=40)

    # 1) Null fault model == serial Gossip: identical collectives in the
    # lowered hot path, bit-identical solve.
    K = 10
    z0 = jnp.zeros((q, n))
    def probe(policy):
        backend = MeshBackend(wmesh, policy=policy)
        def worker(y_m, t_m, z0r):
            a, chol, _ = admm._worker_stats_local(y_m, t_m, 1e-2, False)
            return admm.worker_admm_iterations(
                backend, a, chol, y_m, t_m, z0r, mu=1e-2, eps_radius=6.0,
                num_iters=K, policy=policy, trace_every=0)
        return backend.lowering_stats(
            worker, yw, tw, replicated=(z0,), key="probe", policy=policy)

    anull = AsyncGossip(rounds=3, topology=Hypercube())
    gser = Gossip(rounds=3, topology=Hypercube(), compress=False)
    ca = probe(anull)["collective_counts"]
    cg = probe(gser)["collective_counts"]
    assert ca == cg, (ca, cg)
    # Both equal the spmdlint wire model's per-mix expectation x K.
    from repro import analysis
    want = {op: K * c
            for op, c in analysis.expected_mix_collectives(anull, m).items()}
    assert ca == want, (ca, want)
    ra = admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(wmesh, policy=anull), **kw)
    rg = admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(wmesh, policy=gser), **kw)
    assert jnp.array_equal(ra.o_star, rg.o_star)

    # 2) Faulty solve: deterministic on the mesh, sim-vs-mesh parity.
    pol = AsyncGossip(rounds=3, topology=Hypercube(),
                      faults=FaultModel(drop=0.2, seed=11))
    mesh_be = MeshBackend(wmesh, policy=pol)
    f1 = admm.admm_ridge_consensus(yw, tw, backend=mesh_be, **kw)
    f2 = admm.admm_ridge_consensus(yw, tw, backend=mesh_be, **kw)
    assert jnp.array_equal(f1.o_star, f2.o_star)
    assert mesh_be.lowerings == 1, mesh_be.cache_info()
    fs = admm.admm_ridge_consensus(
        yw, tw, backend=SimulatedBackend(m, policy=pol), **kw)
    rel = float(jnp.linalg.norm(fs.o_star - f1.o_star)
                / jnp.linalg.norm(fs.o_star))
    assert rel < 1e-4, rel

    # 3) Full faulty training + mid-run kill/resume on the mesh.
    cfg = ssfn.SSFNConfig(input_dim=10, num_classes=3, num_layers=2,
                          hidden=24, admm_iters=60)
    kx, kt, kinit = jax.random.split(jax.random.PRNGKey(2), 3)
    xw = jax.random.normal(kx, (m, 10, 24))
    labels = jax.random.randint(kt, (m, 24), 0, 3)
    tw2 = jax.nn.one_hot(labels, 3).transpose(0, 2, 1)

    train_be = MeshBackend(wmesh, policy=pol)
    pf, logf = layerwise.train_decentralized_ssfn(
        xw, tw2, cfg, kinit, backend=train_be)
    # L=2 -> 3 layer solves, 3 distinct shapes, zero fault retraces.
    assert train_be.lowerings == 3, train_be.cache_info()

    ckpt = tempfile.mkdtemp()
    layerwise.train_decentralized_ssfn(
        xw, tw2, cfg, kinit, backend=train_be,
        checkpoint_dir=ckpt, stop_after_layer=0)   # 'crash' after layer 0
    pr, logr = layerwise.train_decentralized_ssfn(
        xw, tw2, cfg, kinit, backend=train_be,
        checkpoint_dir=ckpt, resume=True)
    for a, b in zip(pf.o, pr.o):
        assert jnp.array_equal(a, b)
    assert logf.comm_scalars == logr.comm_scalars
    assert np.array_equal(logf.admm_objective, logr.admm_objective)
    print("ELASTIC8_OK", rel)
    """)
    assert "ELASTIC8_OK" in out


def test_byzantine_robust_consensus_on_8_devices():
    """The robustness acceptance test on a real M=8 ``workers`` mesh:
    one signflip attacker on a 2x4 torus — ``trimmed:f=1`` converges to
    the honest-data solution while the non-robust gossip path fails the
    same bound (and a nanbomb attacker NaNs it outright); the attack
    schedule is deterministic inside ONE cached lowering; zero-attacker
    trimmed stays bit-identical to plain serial gossip on the mesh."""
    out = run_subprocess("""
    from repro.core import admm
    from repro.core.backend import MeshBackend, SimulatedBackend
    from repro.core.policy import AsyncGossip, Gossip, parse_policy
    from repro.core.topology import Torus
    from repro.launch.mesh import make_worker_mesh

    m, n, q, j = 8, 16, 3, 160
    wmesh = make_worker_mesh(m)
    ky, kt = jax.random.split(jax.random.PRNGKey(4))
    y = jax.random.normal(ky, (n, j))
    t = jax.random.normal(kt, (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=40)

    # Honest-data reference: the attacker's shard is unlearnable (every
    # payload it emits is corrupted), so worker 3's data leaves the pool.
    keep = np.array([i for i in range(m) if i != 3])
    oh = admm.admm_ridge_consensus(
        yw[keep], tw[keep], backend=SimulatedBackend(m - 1), **kw)
    def rel(res):
        return float(jnp.linalg.norm(res.o_star - oh.o_star)
                     / jnp.linalg.norm(oh.o_star))

    pol = parse_policy("trimmed:f=1:rounds=3:byz=3:attack=signflip@torus:2x4")
    mesh_be = MeshBackend(wmesh, policy=pol)
    rob = admm.admm_ridge_consensus(yw, tw, backend=mesh_be, **kw)
    rob2 = admm.admm_ridge_consensus(yw, tw, backend=mesh_be, **kw)
    # Deterministic attack schedule, one lowering for the (policy,
    # fault-model) pair even across repeat solves.
    assert jnp.array_equal(rob.o_star, rob2.o_star)
    assert mesh_be.lowerings == 1, mesh_be.cache_info()
    # Sim-vs-mesh parity under attack (same seeded draws both paths).
    sim = admm.admm_ridge_consensus(
        yw, tw, backend=SimulatedBackend(m, policy=pol), **kw)
    rel_pair = float(jnp.linalg.norm(sim.o_star - rob.o_star)
                     / jnp.linalg.norm(sim.o_star))
    assert rel_pair < 1e-4, rel_pair

    # Robust converges; the non-robust path fails the same bound.
    r_rob = rel(rob)
    vuln = AsyncGossip(rounds=3, topology=Torus(2, 4), faults=pol.faults)
    r_vul = rel(admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(wmesh, policy=vuln), **kw))
    assert np.isfinite(r_rob) and r_rob < 0.15, r_rob
    assert (not np.isfinite(r_vul)) or r_vul > 4 * r_rob, (r_rob, r_vul)

    # nanbomb: robust screens the NaN payloads out entirely; the
    # non-robust mix is destroyed by them.
    nb = parse_policy("trimmed:f=1:rounds=3:byz=3:attack=nanbomb@torus:2x4")
    rob_nb = admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(wmesh, policy=nb), **kw)
    assert np.isfinite(rel(rob_nb)) and rel(rob_nb) < 0.15, rel(rob_nb)
    vuln_nb = AsyncGossip(rounds=3, topology=Torus(2, 4), faults=nb.faults)
    r_vnb = rel(admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(wmesh, policy=vuln_nb), **kw))
    assert not np.isfinite(r_vnb), r_vnb

    # Zero attackers: trimmed == plain serial gossip, bit for bit.
    clean = parse_policy("trimmed:f=1:rounds=3@torus:2x4")
    a = admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(wmesh, policy=clean), **kw)
    b = admm.admm_ridge_consensus(
        yw, tw, backend=MeshBackend(
            wmesh, policy=Gossip(rounds=3, topology=Torus(2, 4),
                                 compress=False)), **kw)
    assert jnp.array_equal(a.o_star, b.o_star)
    print("BYZ8_OK", r_rob, r_vul)
    """)
    assert "BYZ8_OK" in out


def test_distributed_admm_on_8_devices():
    out = run_subprocess("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from repro.core import admm
    from repro.core.readout import admm_solve_sharded

    n, q, j = 16, 3, 256   # J/8 workers = 32 samples > n: full-rank locals
    y = jax.random.normal(jax.random.PRNGKey(0), (n, j))
    t = jax.random.normal(jax.random.PRNGKey(1), (q, j))
    fn = shard_map(
        partial(admm_solve_sharded, mu=1e-2, eps_radius=6.0, num_iters=300,
                axis_names=("data", "model")),
        mesh=mesh,
        in_specs=(P(None, ("data", "model")), P(None, ("data", "model"))),
        out_specs=jax.tree.map(lambda _: P(), __import__(
            "repro.core.readout", fromlist=["ShardedADMMResult"]
        ).ShardedADMMResult(z=0, objective=0)),
        check_rep=False)
    with mesh:
        res = jax.jit(fn)(y, t)
    oracle = admm.exact_constrained_ridge(y, t, eps_radius=6.0)
    rel = float(jnp.linalg.norm(res.z - oracle) / jnp.linalg.norm(oracle))
    assert rel < 1e-3, rel
    print("ADMM8_OK", rel)
    """)
    assert "ADMM8_OK" in out


def test_spmdlint_wire_mutations_on_8_devices():
    """The wire checker's acceptance mutations on a real M=8 mesh: a
    policy that lies about its wire width trips ``wire-payload``, one
    that misdeclares its eq.-15 scalar count trips ``wire-declaration``,
    and the corresponding honest policies stay clean."""
    out = run_subprocess("""
    import dataclasses
    from repro import analysis
    from repro.core.backend import MeshBackend
    from repro.core.policy import Gossip, parse_policy
    from repro.launch.mesh import make_worker_mesh

    m = 8
    wmesh = make_worker_mesh(m)
    backend = MeshBackend(wmesh)

    # Clean tree first: representative grammar entries honor the
    # declared budget end to end.
    for spec in ("exact", "gossip:3:2", "gossip:2:wire=bf16", "quantized:8"):
        pol = parse_policy(spec)
        found = analysis.check_wire_contract(
            pol, backend, num_iters=4, subject=spec)
        assert found == [], (spec, [f.render() for f in found])

    # Mutation 1: declare a 16-bit wire while shipping f32 payloads.
    @dataclasses.dataclass(frozen=True)
    class LyingGossip(Gossip):
        mode_name = "lying-gossip"

        @property
        def wire_bits(self):
            return 16

    found = analysis.check_wire_contract(
        LyingGossip(rounds=2), backend, num_iters=4, subject="lying")
    assert "wire-payload" in {f.check for f in found}, [
        f.render() for f in found]

    # Mutation 2: comm_scalars drifts off the closed form.
    @dataclasses.dataclass(frozen=True)
    class Misdeclared(Gossip):
        mode_name = "misdeclared-gossip"

        def comm_scalars(self, *, scalars, num_consensus, num_workers=None):
            return super().comm_scalars(
                scalars=scalars, num_consensus=num_consensus,
                num_workers=num_workers) + scalars

    found = analysis.check_wire_contract(
        Misdeclared(rounds=2), backend, num_iters=4, subject="misdeclared")
    assert "wire-declaration" in {f.check for f in found}, [
        f.render() for f in found]
    print("SPMDLINT8_OK")
    """)
    assert "SPMDLINT8_OK" in out
