import jax
import pytest

# Tests run on the single real CPU device (the dry-run process sets its own
# XLA_FLAGS; never set device-count flags here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
