"""ADMM solver tests: centralized equivalence is THE paper claim."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.testing import given, settings, st

from repro.core import admm, consensus, topology


def _problem(key, n, q, j, m):
    ky, kt = jax.random.split(key)
    y = jax.random.normal(ky, (n, j))
    t = jax.random.normal(kt, (q, j))
    yw = y.reshape(n, m, j // m).transpose(1, 0, 2)
    tw = t.reshape(q, m, j // m).transpose(1, 0, 2)
    return y, t, yw, tw


def test_decentralized_matches_exact_oracle():
    y, t, yw, tw = _problem(jax.random.PRNGKey(0), 32, 5, 400, 4)
    eps = 10.0
    oracle = admm.exact_constrained_ridge(y, t, eps_radius=eps)
    res = admm.admm_ridge_consensus(yw, tw, mu=1e-2, eps_radius=eps, num_iters=300)
    rel = float(jnp.linalg.norm(res.o_star - oracle) / jnp.linalg.norm(oracle))
    assert rel < 1e-4, rel


def test_centralized_equals_decentralized_at_convergence():
    y, t, yw, tw = _problem(jax.random.PRNGKey(1), 24, 4, 240, 6)
    eps = 8.0
    cen = admm.centralized_ridge_admm(y, t, mu=1e-2, eps_radius=eps, num_iters=400)
    dec = admm.admm_ridge_consensus(yw, tw, mu=1e-2, eps_radius=eps, num_iters=400)
    rel = float(
        jnp.linalg.norm(cen.o_star - dec.o_star) / jnp.linalg.norm(cen.o_star)
    )
    assert rel < 1e-4, rel


def test_gossip_consensus_preserves_equivalence():
    """dSSFN over a sparse circular graph (paper topology) still converges
    to the centralized solution once gossip rounds are sufficient."""
    y, t, yw, tw = _problem(jax.random.PRNGKey(2), 16, 3, 160, 8)
    eps = 6.0
    h = topology.circular_mixing_matrix(8, 2)
    rounds = topology.gossip_rounds_for_tolerance(h, 1e-9)
    cfn = consensus.make_consensus_fn("gossip", h=h, num_rounds=rounds)
    dec = admm.admm_ridge_consensus(
        yw, tw, mu=1e-2, eps_radius=eps, num_iters=200, consensus_fn=cfn
    )
    oracle = admm.exact_constrained_ridge(y, t, eps_radius=eps)
    rel = float(jnp.linalg.norm(dec.o_star - oracle) / jnp.linalg.norm(oracle))
    assert rel < 1e-3, rel


def test_projection_feasibility():
    """Z iterates always satisfy the Frobenius constraint."""
    _, _, yw, tw = _problem(jax.random.PRNGKey(3), 16, 3, 160, 4)
    eps = 0.5  # tight ball: projection active
    res = admm.admm_ridge_consensus(yw, tw, mu=1e-1, eps_radius=eps, num_iters=50)
    assert float(jnp.linalg.norm(res.o_star)) <= eps * (1 + 1e-5)


def test_objective_decreases_overall():
    _, _, yw, tw = _problem(jax.random.PRNGKey(4), 16, 3, 160, 4)
    res = admm.admm_ridge_consensus(yw, tw, mu=1e-2, eps_radius=10.0, num_iters=100)
    obj = np.asarray(res.trace.objective)
    assert obj[-1] < obj[0]
    # primal residual shrinks
    assert res.trace.primal_residual[-1] < res.trace.primal_residual[0]


@given(
    n=st.sampled_from([8, 16, 24]),
    q=st.sampled_from([2, 3, 5]),
    m=st.sampled_from([1, 2, 4]),
    mu=st.sampled_from([1e-3, 1e-2, 1e-1]),
)
@settings(max_examples=12, deadline=None)
def test_admm_solution_feasible_and_finite(n, q, m, mu):
    j = 40 * m
    _, _, yw, tw = _problem(jax.random.PRNGKey(n * q * m), n, q, j, m)
    eps = 2.0 * q
    res = admm.admm_ridge_consensus(yw, tw, mu=mu, eps_radius=eps, num_iters=60)
    assert bool(jnp.all(jnp.isfinite(res.o_star)))
    assert float(jnp.linalg.norm(res.o_star)) <= eps * (1 + 1e-4)


def test_projection_operator():
    z = jnp.ones((3, 4))
    out = admm.project_frobenius(z, 1.0)
    assert abs(float(jnp.linalg.norm(out)) - 1.0) < 1e-6
    z_small = 0.01 * jnp.ones((3, 4))
    assert jnp.allclose(admm.project_frobenius(z_small, 1.0), z_small)


def test_pallas_gram_path_matches_default():
    """ADMM with the Pallas gram kernel == einsum path."""
    _, _, yw, tw = _problem(jax.random.PRNGKey(5), 128, 3, 512, 2)
    kw = dict(mu=1e-2, eps_radius=6.0, num_iters=30)
    a = admm.admm_ridge_consensus(yw, tw, **kw)
    b = admm.admm_ridge_consensus(yw, tw, use_kernels=True, **kw)
    rel = float(jnp.linalg.norm(a.o_star - b.o_star) / jnp.linalg.norm(a.o_star))
    assert rel < 1e-4, rel
